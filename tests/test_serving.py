"""Multi-tenant serving (ISSUE 3): AdapterLibrary tenant registry
(resolve/fuse round-trip, partial-chain registration, unknown-tenant
errors), mixed-tenant batch ≡ per-tenant sequential generation, the
tenant-routed fused kernel, per-row decode depths, continuous batching and
the tenant checkpoint path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.adapters import (ActiveAdapters, AdapterLibrary,
                                 adapter_apply_routed)
from repro.launch.serve import Request, ServeEngine, generate
from repro.models import transformer as T

CFG = get_smoke_config("qwen2_0_5b")
KEY = jax.random.PRNGKey(11)


def perturbed(base, seed, scale=0.02):
    k = jax.random.PRNGKey(seed)
    return jax.tree_util.tree_map(
        lambda x: x + scale * jax.random.normal(k, x.shape, x.dtype), base)


@pytest.fixture(scope="module")
def setup():
    params = T.init_lm(KEY, CFG)
    base = T.init_adapters(KEY, CFG)
    return params, base


# ================================================================= library
def test_library_resolve_fuse_roundtrip(setup):
    _, base = setup
    lib = AdapterLibrary()
    a, b = perturbed(base, 1), perturbed(base, 2)
    lib.add("a", a)
    lib.add("b", b)
    # resolve by name is the identity
    assert lib.resolve("a") is a
    # active composition: single active resolves to the stack itself,
    # multi-active resolves to the (uniform) fusion
    lib.set_active("a")
    assert lib.resolve() is a
    lib.set_active("a", "b")
    np.testing.assert_allclose(
        np.asarray(lib.resolve()["down"]),
        np.asarray(0.5 * a["down"] + 0.5 * b["down"]), rtol=1e-6)


def test_library_fuse_matches_manual_weighted_average(setup):
    _, base = setup
    lib = AdapterLibrary()
    a, b = perturbed(base, 1), perturbed(base, 2)
    lib.add("a", a)
    lib.add("b", b)
    fused = lib.fuse(weights=[0.3, 0.7], names=["a", "b"], into="ab")
    for leaf in ("down", "up"):
        np.testing.assert_allclose(
            np.asarray(fused[leaf]),
            np.asarray(0.3 * a[leaf] + 0.7 * b[leaf]), rtol=1e-6)
    # the synthetic tenant is registered with its own slot
    assert "ab" in lib and lib.tenant_id("ab") == 2
    np.testing.assert_allclose(np.asarray(lib.resolve("ab")["down"]),
                               np.asarray(fused["down"]))


def test_library_partial_chain_registration(setup):
    """A chain-tuned window checkpoint registers through its ActiveAdapters
    spec: the window scatters into the library base, prefix/suffix stay the
    base's."""
    _, base = setup
    L = CFG.total_chain_layers
    spec = ActiveAdapters.window(L, 1, 1)
    window = perturbed(jax.tree_util.tree_map(lambda x: x[1:2], base), 5)
    lib = AdapterLibrary(base=base)
    lib.add("chain", window, spec=spec)
    got = lib.resolve("chain")
    np.testing.assert_allclose(np.asarray(got["down"][1]),
                               np.asarray(window["down"][0]))
    np.testing.assert_allclose(np.asarray(got["down"][0]),
                               np.asarray(base["down"][0]))
    # no base -> partial registration must fail loudly
    with pytest.raises(ValueError, match="base"):
        AdapterLibrary().add("chain", window, spec=spec)


def test_library_unknown_tenant_errors(setup):
    _, base = setup
    lib = AdapterLibrary()
    lib.add("a", base)
    with pytest.raises(KeyError, match="unknown tenant 'nope'"):
        lib.tenant_id("nope")
    with pytest.raises(KeyError, match="unknown tenant"):
        lib.resolve("nope")
    with pytest.raises(KeyError):
        lib.set_active("a", "nope")
    with pytest.raises(KeyError):
        lib.fuse(names=["a", "nope"])
    with pytest.raises(ValueError, match="empty library"):
        AdapterLibrary().stacked()


def test_library_stacked_layout_and_cache(setup):
    _, base = setup
    lib = AdapterLibrary()
    for i in range(3):
        lib.add(f"t{i}", perturbed(base, i))
    stacked = lib.stacked()
    assert stacked["down"].shape == (3,) + base["down"].shape
    assert lib.stacked() is stacked          # cached
    scan = lib.stacked_scan()
    L = base["down"].shape[0]
    assert scan["down"].shape[:2] == (L, 3)  # (L, T, ...) for the layer scan
    assert lib.stacked_scan() is scan        # cached
    lib.add("t3", perturbed(base, 3))
    assert lib.stacked() is not stacked      # registration invalidates
    assert lib.stacked_scan() is not scan
    assert lib.tenant_ids(["t2", "t0"]).tolist() == [2, 0]


# ====================================================== routed adapter apply
def test_adapter_apply_routed_kernel_matches_xla(setup):
    """The tenant-routed Pallas kernel (scalar-prefetched ids) must equal the
    gather+einsum XLA fallback and per-row single-tenant applies."""
    from repro.core.adapters import adapter_apply

    _, base = setup
    lib = AdapterLibrary()
    for i in range(3):
        lib.add(f"t{i}", perturbed(base, i, scale=0.1))
    layer0 = jax.tree_util.tree_map(lambda x: x[:, 0], lib.stacked())  # (T,...)
    h = jax.random.normal(KEY, (5, 7, CFG.d_model))
    ids = jnp.asarray([2, 0, 1, 1, 0], jnp.int32)
    xla = adapter_apply_routed(layer0, h, ids, CFG, use_kernel=False)
    kern = adapter_apply_routed(layer0, h, ids, CFG, use_kernel=True)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(xla), atol=1e-5)
    for row, t in enumerate(ids.tolist()):
        one = jax.tree_util.tree_map(lambda x: x[t], layer0)
        ref = adapter_apply(one, h[row:row + 1], CFG, use_kernel=False)
        np.testing.assert_allclose(np.asarray(xla[row:row + 1]),
                                   np.asarray(ref), atol=1e-5)


# ============================================================ mixed batches
def _engine(params, base, n_tenants=3):
    engine = ServeEngine(params, CFG, base)
    names = [engine.register_tenant(f"t{i}", stack=perturbed(base, i))
             for i in range(n_tenants)]
    return engine, names


def test_mixed_tenant_batch_matches_per_tenant_rows(setup):
    """Acceptance bar: one jitted decode serves a batch whose rows use ≥ 3
    different tenant stacks (+ a fused synthetic tenant), row-for-row equal
    to per-tenant sequential generation."""
    params, base = setup
    engine, names = _engine(params, base)
    engine.fuse_tenants("fused", names[:2], weights=[0.25, 0.75])
    names = names + ["fused"]
    B, P, G = 6, 10, 8
    prompts = jax.random.randint(KEY, (B, P), 4, CFG.vocab_size)
    rows = [names[i % len(names)] for i in range(B)]
    assert len(set(rows)) >= 3
    mixed = engine.generate(prompts, rows, G)
    for name in set(rows):
        sel = jnp.asarray([i for i, t in enumerate(rows) if t == name])
        ref = generate(params, engine.library.resolve(name), CFG,
                       prompts[sel], G)
        np.testing.assert_array_equal(np.asarray(mixed[sel]),
                                      np.asarray(ref))


def test_unknown_tenant_batch_errors(setup):
    params, base = setup
    engine, _ = _engine(params, base, n_tenants=1)
    prompts = jax.random.randint(KEY, (2, 6), 4, CFG.vocab_size)
    with pytest.raises(KeyError, match="unknown tenant"):
        engine.generate(prompts, ["t0", "ghost"], 4)


def test_decode_step_vector_idx_matches_scalar(setup):
    """A uniform (B,) idx vector must reproduce the scalar-idx decode — the
    per-row depth path used by continuous batching."""
    params, base = setup
    B, S = 2, 9
    toks = jax.random.randint(KEY, (B, S), 4, CFG.vocab_size)
    lg, pcache, _ = T.prefill(params, base, {"tokens": toks}, CFG)

    def pad(x):
        if x.ndim >= 3 and x.shape[2] == S and x.shape[1] == B:
            w = [(0, 0)] * x.ndim
            w[2] = (0, 2)
            return jnp.pad(x, w)
        return x

    cache = jax.tree_util.tree_map(pad, pcache)
    nxt = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
    lg_s, cache_s, _ = T.decode_step(params, base, nxt, cache, S, CFG)
    lg_v, cache_v, _ = T.decode_step(params, base, nxt, cache,
                                     jnp.full((B,), S, jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(lg_v), np.asarray(lg_s), atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(cache_v),
                    jax.tree_util.tree_leaves(cache_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_continuous_batching_matches_static(setup):
    """Slot-based admission over an oversubscribed queue must emit exactly
    the tokens of the static mixed-tenant batch, per request."""
    params, base = setup
    engine, names = _engine(params, base)
    P, G = 8, 6
    n_req = 7
    prompts = jax.random.randint(KEY, (n_req, P), 4, CFG.vocab_size)
    tenants = [names[i % len(names)] for i in range(n_req)]
    reqs = [Request(i, np.asarray(prompts[i]), tenants[i], G)
            for i in range(n_req)]
    served = engine.serve(reqs, slots=3, prompt_len=P, max_new_cap=G)
    ref = engine.generate(prompts, tenants, G)
    for i in range(n_req):
        np.testing.assert_array_equal(served[i], np.asarray(ref[i]))


# ==================================================== per-tenant sampling
def test_sample_jit_respects_topk_and_temperature():
    """The per-row sampler: greedy rows (temp ≤ 0) are exact argmax, top-k
    rows never leave their top-k set, and top_k=1 is argmax regardless of
    temperature — per-row params routed like tenant ids."""
    from repro.launch.serve import _sample_jit

    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (4, 32))
    greedy = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
    # row 0 greedy, row 1 top-1 sampling (≡ greedy), rows 2/3 top-k sampled
    temps = jnp.asarray([0.0, 5.0, 1.0, 2.0], jnp.float32)
    topks = jnp.asarray([0, 1, 4, 8], jnp.int32)
    nopp = jnp.ones((4,), jnp.float32)           # top_p=1.0: nucleus off
    seen = set()
    for i in range(24):
        tok = np.asarray(_sample_jit(logits, temps, topks, nopp,
                                     jax.random.fold_in(key, i)))
        assert tok[0] == greedy[0]
        assert tok[1] == greedy[1]
        for row, k in ((2, 4), (3, 8)):
            topset = np.argsort(np.asarray(logits[row]))[-k:]
            assert tok[row] in topset, (row, k)
        seen.add(int(tok[3]))
    assert len(seen) > 1        # hot rows actually sample
    # top_k ≥ V is "no cut", identical to top_k = 0 (no negative wrap)
    wide = _sample_jit(logits, temps, jnp.asarray([0, 1, 32 + 9, 8]), nopp,
                       jax.random.fold_in(key, 0))
    base = _sample_jit(logits, temps, jnp.asarray([0, 1, 0, 8]), nopp,
                       jax.random.fold_in(key, 0))
    np.testing.assert_array_equal(np.asarray(wide), np.asarray(base))


def test_serve_sampling_defaults_greedy_and_topk1_exact(setup):
    """Tenants without SamplingParams decode greedily (bit-identical to the
    pre-sampling loop == static generation); a tenant with high temperature
    but top_k=1 must still emit exactly the greedy tokens."""
    params, base = setup
    engine, names = _engine(params, base)
    engine.set_sampling(names[1], temperature=7.5, top_k=1)
    P, G, n_req = 8, 6, 5
    prompts = jax.random.randint(KEY, (n_req, P), 4, CFG.vocab_size)
    tenants = [names[i % len(names)] for i in range(n_req)]
    reqs = [Request(i, np.asarray(prompts[i]), tenants[i], G)
            for i in range(n_req)]
    served = engine.serve(reqs, slots=3, prompt_len=P, max_new_cap=G)
    ref = engine.generate(prompts, tenants, G)     # greedy reference
    for i in range(n_req):
        np.testing.assert_array_equal(served[i], np.asarray(ref[i]))


def test_serve_mixed_sampling_reproducible_no_rejits(setup):
    """A mixed greedy/sampling batch: sampled tenants diverge from greedy,
    greedy tenants don't, reruns with the same seed are bit-identical, and
    sampling params ride as traced data (no new decode compilations)."""
    from repro.launch.serve import SamplingParams, _decode_jit, _sample_jit

    params, base = setup
    engine, names = _engine(params, base)
    engine.set_sampling(names[2], temperature=3.0, top_k=8)
    with pytest.raises(KeyError):
        engine.set_sampling("ghost", temperature=1.0)
    assert engine._tenant_sampling(names[2]) == SamplingParams(3.0, 8)
    P, G, n_req = 8, 6, 6
    prompts = jax.random.randint(KEY, (n_req, P), 4, CFG.vocab_size)
    tenants = [names[i % len(names)] for i in range(n_req)]
    reqs = [Request(i, np.asarray(prompts[i]), tenants[i], G)
            for i in range(n_req)]
    ref = engine.generate(prompts, tenants, G)     # greedy reference
    served = engine.serve(reqs, slots=3, prompt_len=P, max_new_cap=G)
    counts = ((_decode_jit._cache_size(), _sample_jit._cache_size())
              if hasattr(_decode_jit, "_cache_size") else None)
    again = engine.serve(reqs, slots=3, prompt_len=P, max_new_cap=G)
    hot = engine.serve(reqs, slots=3, prompt_len=P, max_new_cap=G,
                       sample_seed=99)
    sampled_rows = [i for i, t in enumerate(tenants) if t == names[2]]
    greedy_rows = [i for i, t in enumerate(tenants) if t != names[2]]
    for i in greedy_rows:
        np.testing.assert_array_equal(served[i], np.asarray(ref[i]))
    assert any(not np.array_equal(hot[i], np.asarray(ref[i]))
               for i in sampled_rows)
    for i in range(n_req):      # same seed → bit-identical replay
        np.testing.assert_array_equal(served[i], again[i])
    if counts is not None:      # params/seed are traced data: no re-jits
        assert (_decode_jit._cache_size(),
                _sample_jit._cache_size()) == counts


def test_tenant_checkpoint_roundtrip(tmp_path, setup):
    """save_adapter_stack → register_tenant(ckpt=...) serves the same rows,
    for both full stacks and partial-chain (spec) checkpoints."""
    from repro.ckpt.io import save_adapter_stack

    params, base = setup
    L = CFG.total_chain_layers
    spec = ActiveAdapters.window(L, 1, 1)
    full = perturbed(base, 21)
    window = perturbed(jax.tree_util.tree_map(lambda x: x[1:2], base), 22)
    p_full = save_adapter_stack(tmp_path / "full.msgpack", full, tenant="f",
                                meta={"l_start": 0})
    p_win = save_adapter_stack(tmp_path / "win.msgpack", window, tenant="w")

    mem = ServeEngine(params, CFG, base)
    mem.register_tenant("f", stack=full)
    mem.register_tenant("w", stack=window, spec=spec)
    disk = ServeEngine(params, CFG, base)
    disk.register_tenant("f", ckpt=p_full)
    disk.register_tenant("w", ckpt=p_win, spec=spec)

    prompts = jax.random.randint(KEY, (2, 6), 4, CFG.vocab_size)
    a = mem.generate(prompts, ["f", "w"], 4)
    b = disk.generate(prompts, ["f", "w"], 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="exactly one"):
        mem.register_tenant("x", stack=full, ckpt=p_full)
