"""Serving correctness: prefill+decode must reproduce the full forward —
the strongest invariant for the KV cache / SSM-state plumbing, checked per
architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T

FAMS = ["gemma_2b",          # dense (MQA + SWA config, but S < window here)
        "qwen2_0_5b",        # dense GQA + qkv bias
        "olmoe_1b_7b",       # moe
        "falcon_mamba_7b",   # ssm
        "hymba_1_5b",        # hybrid
        "qwen2_vl_72b"]      # vlm / mrope


def _setup(arch, B=2, S=12):
    cfg = get_smoke_config(arch)
    if cfg.sliding_window is not None:
        cfg = cfg.replace(sliding_window=None)   # exact-match test: full attn
    if cfg.family == "moe":
        # dropless capacity: capacity-based routing otherwise truncates
        # *differently* for batched vs incremental execution (expected),
        # which would mask true cache bugs in this exact-match test
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(7)
    params = T.init_lm(key, cfg)
    adapters = T.init_adapters(key, cfg)
    # make adapters non-trivial so the test also covers adapter plumbing
    adapters = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jax.random.normal(key, x.shape, x.dtype), adapters)
    toks = jax.random.randint(key, (B, S), 4, cfg.vocab_size)
    return cfg, params, adapters, toks


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_full_forward(arch):
    cfg, params, adapters, toks = _setup(arch)
    B, S = toks.shape
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch = {"embeds": jax.random.normal(jax.random.PRNGKey(1),
                                             (B, S, cfg.d_model)) * 0.1}
    full, _ = T.forward_full(params, adapters, batch, cfg, remat=False)

    # token-by-token decode from an empty cache
    cache = T.init_cache(cfg, B, S + 2)
    idx = 0
    logits_steps = []
    for t in range(S):
        if cfg.family == "vlm":
            emb = batch["embeds"][:, t:t + 1]
            lg, cache, idx = T.decode_step(params, adapters, None, cache, idx,
                                           cfg, embeds=emb)
        else:
            lg, cache, idx = T.decode_step(params, adapters, toks[:, t:t + 1],
                                           cache, idx, cfg)
        logits_steps.append(lg)
    dec = jnp.stack(logits_steps, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "falcon_mamba_7b", "hymba_1_5b"])
def test_prefill_then_decode_consistent(arch):
    """prefill(tokens[:k]) + decode(tokens[k:]) == full forward logits at the
    decoded positions."""
    cfg, params, adapters, toks = _setup(arch)
    B, S = toks.shape
    k = S // 2
    full, _ = T.forward_full(params, adapters, {"tokens": toks}, cfg, remat=False)

    lg, pcache, n = T.prefill(params, adapters, {"tokens": toks[:, :k]}, cfg)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full[:, k - 1], np.float32),
                               atol=2e-3, rtol=2e-3)
    # pad kv entries to S+2 and continue decoding
    def pad(x):
        if x.ndim >= 3 and x.shape[2] == k:      # (L, B, S, KV, hd) kv caches
            padw = [(0, 0)] * x.ndim
            padw[2] = (0, S + 2 - k)
            return jnp.pad(x, padw)
        return x
    cache = jax.tree_util.tree_map(pad, pcache)
    idx = k
    for t in range(k, S):
        lg, cache, idx = T.decode_step(params, adapters, toks[:, t:t + 1],
                                       cache, idx, cfg)
        if t + 1 < S:
            np.testing.assert_allclose(np.asarray(lg, np.float32),
                                       np.asarray(full[:, t], np.float32),
                                       atol=2e-3, rtol=2e-3)


def test_sliding_window_decode_ring_buffer():
    """SWA ring buffer: decode with window W only attends to the last W
    tokens — must match a full-attention decode over those tokens."""
    arch = "qwen2_0_5b"
    cfg = get_smoke_config(arch).replace(sliding_window=4)
    key = jax.random.PRNGKey(3)
    params = T.init_lm(key, cfg)
    adapters = T.init_adapters(key, cfg)
    B, S = 1, 10
    toks = jax.random.randint(key, (B, S), 4, cfg.vocab_size)
    cache = T.init_cache(cfg, B, S)            # capped to window internally
    assert cache["k"].shape[2] == 4
    idx = 0
    for t in range(S):
        lg, cache, idx = T.decode_step(params, adapters, toks[:, t:t + 1],
                                       cache, idx, cfg)
    assert not bool(jnp.any(jnp.isnan(lg)))
