"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED variant (≤2 layers, d_model ≤ 512, ≤4 experts) runs one forward and
one chain train step on CPU — output shapes + no NaNs.  Full configs are
exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_smoke_config
from repro.core.adapters import ActiveAdapters
from repro.core.dlct import make_schedule
from repro.fed.strategies import PlanEngine, TrainablePlan
from repro.models import transformer as T
from repro.models.config import ChainConfig
from repro.optim.base import make_optimizer
from repro.train.losses import IGNORE


def make_batch(cfg, B=2, S=16, S_src=24):
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.02
        batch["positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
        del batch["tokens"]
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(key, (B, S_src, cfg.d_model)) * 0.02
    return batch


@pytest.fixture(scope="module")
def states():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            key = jax.random.PRNGKey(42)
            cache[arch] = (cfg, T.init_lm(key, cfg), T.init_adapters(key, cfg))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_exact(arch):
    """The full config carries the published hyper-parameters."""
    cfg = get_config(arch)
    expected = {
        "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen2_0_5b": (24, 896, 14, 2, 4864, 151936),
        "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102400),
        "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "falcon_mamba_7b": (64, 4096, 1, 1, 0, 65024),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen2_vl_72b": (80, 8192, 64, 8, 29568, 152064),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_smoke(arch, states):
    cfg, params, adapters = states(arch)
    batch = make_batch(cfg)
    logits, aux = T.forward_full(params, adapters, batch, cfg, remat=False)
    B = 2
    assert logits.shape == (B, 16, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    if cfg.family == "moe":
        assert float(aux["load_balance"]) > 0.0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_chain_train_step_smoke(arch, states):
    """One GPO/DLCT local step via the plan engine: loss finite, only window
    adapters move."""
    cfg, params, adapters = states(arch)
    chain = ChainConfig(window=1, lam=0.2, lr=1e-2, optimizer="sgd",
                        train_head=False)
    sched = make_schedule(cfg, l_start=0, window=1)
    seg = sched.segments(0)
    engine = PlanEngine(cfg, chain, make_optimizer(chain.optimizer, chain.lr))
    plan = TrainablePlan(
        adapters=ActiveAdapters.window(cfg.total_chain_layers, seg.prefix,
                                       seg.window),
        train_head=False, loss="gpo", lam=chain.lam)
    trainable = engine.init_trainable(plan, params, adapters, None)
    opt_state = engine.opt.init(trainable)
    batch = make_batch(cfg)
    new_tr, _, loss, parts = engine.local_step(plan)(
        trainable, opt_state, params, adapters, batch, {})
    assert np.isfinite(float(loss)), arch
    moved = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))),
        jax.tree_util.tree_map(lambda a, b: a - b, new_tr["adapters"],
                               trainable["adapters"]), 0.0)
    assert moved > 0.0, f"{arch}: window adapters did not update"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step_smoke(arch, states):
    cfg, params, adapters = states(arch)
    B = 2
    enc_len = 24 if cfg.is_encdec else None
    cache = T.init_cache(cfg, B, 32, enc_len=enc_len)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache, idx = T.decode_step(params, adapters, tok, cache, 0, cfg,
                                       enc_len=enc_len)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert idx == 1


def test_vocab_padding_masks_logits():
    cfg = get_smoke_config("hymba_1_5b").replace(vocab_size=500)
    key = jax.random.PRNGKey(0)
    params, adapters = T.init_lm(key, cfg), T.init_adapters(key, cfg)
    logits, _ = T.forward_full(params, adapters, make_batch(cfg), cfg, remat=False)
    assert cfg.padded_vocab == 512
    assert float(jnp.max(logits[..., 500:])) < -1e8
