import pytest

try:
    import hypothesis
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


def given_or_grid(grid, strategies, **settings):
    """Property-test decorator: hypothesis ``@given`` when available, else a
    fixed ``@pytest.mark.parametrize`` grid so minimal environments keep
    coverage instead of erroring at collection.

    ``grid``: list of kwargs dicts (the fallback samples).
    ``strategies``: callable ``st -> dict`` built lazily so modules import
    without hypothesis installed.
    ``settings``: hypothesis.settings overrides (e.g. ``max_examples``).
    """
    if HAVE_HYPOTHESIS:
        import hypothesis.strategies as st
        kw = dict(deadline=None,
                  suppress_health_check=[hypothesis.HealthCheck.too_slow])
        kw.update(settings)

        def deco(fn):
            return hypothesis.settings(**kw)(
                hypothesis.given(**strategies(st))(fn))

        return deco

    keys = sorted(grid[0])
    params = [tuple(case[k] for k in keys) for case in grid]

    def deco(fn):
        return pytest.mark.parametrize(",".join(keys), params)(fn)

    return deco
