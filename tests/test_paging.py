"""Paged KV-cache serving (ISSUE 9): the PageTable allocator (free-list
reuse, refcounted shared prefixes, exhaustion), the paged-attention kernel
vs the gather fallback, paged ≡ dense serve equality across families and
slot-lifecycle edge cases, the adapter library's host/LRU tier, nucleus
sampling, and the serve-loop admission guards."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.adapters import AdapterLibrary, adapter_stack_init
from repro.core.memory import (paged_kv_bytes, resident_library_bytes,
                               serve_kv_bytes)
from repro.core.paging import PageTable
from repro.launch.serve import (Request, SamplingParams, ServeEngine,
                                _claim_slot, _sample_jit)
from repro.models import transformer as T

CFG = get_smoke_config("qwen2_0_5b")
KEY = jax.random.PRNGKey(5)


def perturbed(base, seed, scale=0.02):
    k = jax.random.PRNGKey(seed)
    return jax.tree_util.tree_map(
        lambda x: x + scale * jax.random.normal(k, x.shape, x.dtype), base)


@pytest.fixture(scope="module")
def setup():
    params = T.init_lm(KEY, CFG)
    base = T.init_adapters(KEY, CFG)
    return params, base


def _engine(params, base, n_tenants=3, capacity=None):
    eng = ServeEngine(params, CFG, base, resident_capacity=capacity)
    names = [eng.register_tenant(f"t{i}", stack=perturbed(base, 100 + i))
             for i in range(n_tenants)]
    return eng, names


def _requests(n, prompt_len, names, seed=3, max_new=(2, 9)):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(4, CFG.vocab_size,
                                    prompt_len).astype(np.int32),
                    names[int(rng.integers(0, len(names)))],
                    int(rng.integers(*max_new))) for i in range(n)]


# ================================================================ PageTable
def test_page_table_admit_release_and_reuse():
    t = PageTable(n_pages=8, page_size=4, slots=2, max_pages=4)
    rows = t.admit(0, 10)                     # ceil(10/4) = 3 pages
    assert (rows[:3] >= 0).all() and (rows[3:] == -1).all()
    assert t.in_use == 3
    first = [int(p) for p in rows[:3]]
    t.release(0)
    assert t.in_use == 0 and (t.rows()[0] == -1).all()
    # LIFO free list: re-admission reuses the released pages
    again = [int(p) for p in t.admit(1, 12)[:3]]
    assert set(again) == set(first)


def test_page_table_exhaustion_and_guards():
    t = PageTable(n_pages=4, page_size=4, slots=3, max_pages=4)
    t.admit(0, 16)                            # takes the whole pool
    assert not t.can_admit(4)
    with pytest.raises(RuntimeError, match="exhausted"):
        t.admit(1, 4)
    with pytest.raises(RuntimeError, match="release"):
        t.admit(0, 4)                         # slot already holds pages
    with pytest.raises(ValueError, match="max_pages"):
        PageTable(8, 4, 2, 2).admit(0, 16)    # horizon overflow
    assert t.peak_in_use == 4


def test_page_table_shared_prefix_refcounts():
    t = PageTable(n_pages=8, page_size=4, slots=3, max_pages=4)
    pages, fresh = t.share_prefix("sys", 8)   # 2 pages, registration pin
    assert fresh and len(pages) == 2
    same, fresh2 = t.share_prefix("sys", 8)
    assert not fresh2 and same == pages
    t.admit(0, 12, shared=pages)              # 2 shared + 1 private
    t.admit(1, 12, shared=pages)
    assert t.in_use == 4                      # 2 shared + 2 private
    t.release(0)
    t.release(1)
    assert t.in_use == 2                      # pin keeps the prefix alive
    t.drop_prefixes()
    assert t.in_use == 0
    with pytest.raises(ValueError, match="aligned"):
        t.share_prefix("odd", 6)


# ========================================================== paged attention
def test_paged_attention_kernel_matches_gather_fallback():
    """The scalar-prefetched kernel (interpret=True) equals the contiguous
    gather + masked-softmax reference, including parked rows (length 0) and
    unallocated (-1) page entries."""
    from repro.kernels.ops import paged_attention

    ks = jax.random.split(KEY, 3)
    B, KV, G, hd, P, ps, mp = 4, 2, 3, 16, 12, 4, 3
    q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32)
    k_pool = jax.random.normal(ks[1], (P, ps, KV, hd), jnp.float32)
    v_pool = jax.random.normal(ks[2], (P, ps, KV, hd), jnp.float32)
    pages = jnp.asarray([[0, 1, 2], [3, 4, -1], [5, -1, -1], [6, 7, 8]],
                        jnp.int32)
    lengths = jnp.asarray([11, 7, 3, 0], jnp.int32)
    out = paged_attention(q, k_pool, v_pool, pages, lengths)

    Kc = k_pool[jnp.maximum(pages, 0)].reshape(B, mp * ps, KV, hd)
    Vc = v_pool[jnp.maximum(pages, 0)].reshape(B, mp * ps, KV, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", q, Kc) / jnp.sqrt(jnp.float32(hd))
    valid = jnp.arange(mp * ps)[None] < lengths[:, None]
    w = jax.nn.softmax(jnp.where(valid[:, None, None, :], s, -1e30), axis=-1)
    ref = jnp.einsum("bkgs,bskh->bkgh", w, Vc)
    ref = jnp.where((lengths > 0)[:, None, None, None], ref, 0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ====================================================== paged ≡ dense serve
@pytest.mark.parametrize("arch", ["qwen2_0_5b", "falcon_mamba_7b",
                                  "hymba_1_5b"])
def test_paged_serve_equals_dense_serve(arch):
    """Row-for-row token equality between the paged pool and the dense slot
    cache under continuous batching, for attention, SSM and hybrid blocks —
    drains, re-admissions and partial tail pages included."""
    cfg = get_smoke_config(arch)
    params = T.init_lm(KEY, cfg)
    base = T.init_adapters(KEY, cfg)
    eng = ServeEngine(params, cfg, base)
    names = [eng.register_tenant(f"t{i}", stack=perturbed(base, 100 + i))
             for i in range(3)]
    rng = np.random.default_rng(7)
    reqs = [Request(i, rng.integers(4, cfg.vocab_size, 12).astype(np.int32),
                    names[i % 3], int(rng.integers(2, 9))) for i in range(7)]
    dense = eng.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8)
    paged = eng.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8,
                      paged=True, page_size=5)     # 17 % 5 ≠ 0: tail pages
    for r in reqs:
        np.testing.assert_array_equal(dense[r.rid], paged[r.rid])
    stats = eng.last_serve_stats
    assert stats["paged"] and stats["pages"]["in_use"] == 0


def test_paged_serve_drained_slot_reuses_pages_and_parks_oob(setup):
    """Slot lifecycle: more requests than slots forces drains + re-admission
    (reusing released pages — peak stays at the concurrent footprint, not
    the cumulative one), and drained rows park without corrupting live
    rows' pages."""
    params, base = setup
    eng, names = _engine(params, base)
    reqs = _requests(9, 12, names, seed=11)
    out = eng.serve(list(reqs), slots=2, prompt_len=12, max_new_cap=8,
                    paged=True, page_size=4)
    for r in reqs:
        assert len(out[r.rid]) == r.max_new
    st = eng.last_serve_stats["pages"]
    # 9 admissions × 5 pages each would be 45 without reuse; two slots
    # can hold at most 2 × ceil(19/4) = 10 concurrently
    assert st["peak_in_use"] <= 10
    ref = eng.serve(list(reqs), slots=2, prompt_len=12, max_new_cap=8)
    for r in reqs:
        np.testing.assert_array_equal(out[r.rid], ref[r.rid])


def test_paged_serve_shared_prefix_exact_and_smaller(setup):
    """Sharing page-aligned common prompt prefixes is bit-exact and strictly
    lowers the peak page footprint."""
    params, base = setup
    eng, names = _engine(params, base)
    rng = np.random.default_rng(0)
    pre = rng.integers(4, CFG.vocab_size, 8).astype(np.int32)
    reqs = [Request(i, np.concatenate(
                [pre, rng.integers(4, CFG.vocab_size, 4).astype(np.int32)]),
                    names[0], 6) for i in range(6)]
    plain = eng.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8,
                      paged=True, page_size=4)
    peak_plain = eng.last_serve_stats["pages"]["peak_in_use"]
    shared = eng.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8,
                       paged=True, page_size=4, shared_prefix_len=8)
    st = eng.last_serve_stats["pages"]
    for r in reqs:
        np.testing.assert_array_equal(plain[r.rid], shared[r.rid])
    assert st["peak_in_use"] < peak_plain
    assert st["prefix_hits"] >= 1 and st["prefix_misses"] == 1
    with pytest.raises(ValueError, match="multiple of page_size"):
        eng.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8,
                  paged=True, page_size=4, shared_prefix_len=6)


def test_paged_serve_backpressure_completes(setup):
    """A pool smaller than slots × worst-case forces admission waits; every
    request still completes at full length.  A pool too small for even one
    request raises instead of spinning."""
    params, base = setup
    eng, names = _engine(params, base)
    reqs = _requests(6, 12, names, seed=2)
    out = eng.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8,
                    paged=True, page_size=4, n_pages=6)
    for r in reqs:
        assert len(out[r.rid]) == r.max_new
    with pytest.raises(RuntimeError, match="pool too small"):
        eng.serve(_requests(2, 12, names, max_new=(8, 9)), slots=2,
                  prompt_len=12, max_new_cap=8, paged=True, page_size=4,
                  n_pages=2)


# ===================================================== serve-loop guards
def test_serve_admission_guard_and_validation(setup):
    """Satellite: admitting into a busy slot raises 'no free slots' instead
    of clobbering the live row; malformed serve calls fail fast."""
    params, base = setup
    eng, names = _engine(params, base)
    with pytest.raises(RuntimeError, match="no free slots"):
        _claim_slot([["rid0", 3, names[0]]], 0, "rid1")
    _claim_slot([None], 0, "rid1")            # free slot: no error
    reqs = _requests(4, 12, names)
    with pytest.raises(ValueError, match="slots >= 1"):
        eng.serve(list(reqs), slots=0, prompt_len=12)
    dup = [Request(7, reqs[0].tokens, names[0], 2),
           Request(7, reqs[1].tokens, names[1], 2)]
    with pytest.raises(ValueError, match="duplicate request ids"):
        eng.serve(dup, slots=2, prompt_len=12)
    bad = [Request(0, np.zeros(5, np.int32), names[0], 2)]
    with pytest.raises(ValueError, match="prompt_len"):
        eng.serve(bad, slots=2, prompt_len=12)


# ======================================================== host / LRU tier
def test_library_lru_resident_set_routes_like_full(setup):
    """route_ids through an R-row resident slab gathers the same stacks as
    registration-order ids through the full (L, T, ...) library."""
    _, base = setup
    T_, R = 8, 3
    stacks = {f"t{i}": perturbed(base, i) for i in range(T_)}
    full, lru = AdapterLibrary(), AdapterLibrary(resident_capacity=R)
    for n, s in stacks.items():
        full.add(n, s)
        lru.add(n, s)
    for batch in (["t0", "t1", "t0", "t2"], ["t3", "t1"],
                  ["t4", "t5", "t6"], ["t0", "t7"]):
        rids = lru.route_ids(batch)
        got = jax.tree_util.tree_map(lambda x: x[:, rids],
                                     lru.stacked_scan())
        want = jax.tree_util.tree_map(
            lambda x: x[:, full.tenant_ids(batch)], full.stacked_scan())
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert lru.stats["evictions"] > 0 and lru.stats["uploads"] > R
    assert 0.0 <= lru.hit_rate < 1.0
    # slab shape is pinned by R: onboarding more tenants can't re-jit
    leaf = jax.tree_util.tree_leaves(lru.stacked_scan())[0]
    assert leaf.shape[1] == R
    with pytest.raises(RuntimeError, match="resident_capacity"):
        lru.route_ids(["t0", "t1", "t2", "t3"])
    with pytest.raises(RuntimeError, match="resident_capacity"):
        lru.route_ids(["t4"], pin=("t0", "t1", "t2"))


def test_serve_through_lru_resident_set_bit_identical(setup):
    """T=8 tenants served through an R=3 resident set equal the fully
    resident library token-for-token, with evictions actually happening."""
    params, base = setup
    engF, names = _engine(params, base, n_tenants=8)
    engL, _ = _engine(params, base, n_tenants=8, capacity=3)
    reqs = _requests(10, 12, names, seed=13)
    a = engF.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8,
                   paged=True, page_size=4)
    b = engL.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8,
                   paged=True, page_size=4)
    for r in reqs:
        np.testing.assert_array_equal(a[r.rid], b[r.rid])
    st = engL.last_serve_stats
    assert st["adapter"]["evictions"] > 0
    assert 0.0 <= st["adapter_hit_rate"] <= 1.0


# ======================================================= nucleus sampling
def test_sample_jit_nucleus_cut():
    """top_p: greedy rows stay exact argmax; p outside (0, 1) is bit-
    identical to no cut; a tiny p collapses to argmax; a mid p never leaves
    the nucleus set."""
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (4, 32)) * 3
    zk = jnp.zeros((4,), jnp.int32)
    greedy = _sample_jit(logits, jnp.zeros(4), zk, jnp.full(4, 0.5), key)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))
    off1 = _sample_jit(logits, jnp.ones(4), zk, jnp.ones(4), key)
    off0 = _sample_jit(logits, jnp.ones(4), zk, jnp.zeros(4), key)
    np.testing.assert_array_equal(np.asarray(off1), np.asarray(off0))
    tiny = _sample_jit(logits, jnp.full(4, 5.0), zk, jnp.full(4, 1e-6), key)
    np.testing.assert_array_equal(np.asarray(tiny),
                                  np.asarray(jnp.argmax(logits, -1)))
    probs = np.asarray(jax.nn.softmax(logits[0]))
    order = np.argsort(-probs)
    nucleus = set(order[(np.cumsum(probs[order]) - probs[order])
                        < 0.5].tolist())
    for i in range(50):
        tok = _sample_jit(logits[:1], jnp.ones(1), zk[:1], jnp.full(1, 0.5),
                          jax.random.fold_in(key, i))
        assert int(tok[0]) in nucleus


def test_serve_with_topp_tenant_reproducible(setup):
    """A top_p tenant serves reproducibly and greedy tenants stay bit-
    identical to the all-greedy run."""
    params, base = setup
    eng, names = _engine(params, base)
    reqs = _requests(6, 12, names, seed=4, max_new=(4, 7))
    ref = eng.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8)
    eng.set_sampling(names[1], temperature=2.0, top_p=0.8)
    assert eng._tenant_sampling(names[1]) == SamplingParams(2.0, 0, 0.8)
    hot = eng.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8)
    again = eng.serve(list(reqs), slots=3, prompt_len=12, max_new_cap=8)
    for r in reqs:
        np.testing.assert_array_equal(hot[r.rid], again[r.rid])
        if r.tenant != names[1]:
            np.testing.assert_array_equal(hot[r.rid], ref[r.rid])
    assert any(not np.array_equal(hot[r.rid], ref[r.rid])
               for r in reqs if r.tenant == names[1])


# ========================================================== memory model
def test_serving_memory_model():
    slots, horizon, ps = 4, 32, 8
    dense = serve_kv_bytes(CFG, slots, horizon)
    worst = paged_kv_bytes(CFG, slots * (horizon // ps), ps)
    assert dense == worst > 0                 # full pool == dense worst case
    assert paged_kv_bytes(CFG, 6, ps) < dense  # long-tail pools shrink
    ssm = get_smoke_config("falcon_mamba_7b")
    assert serve_kv_bytes(ssm, slots, horizon) == 0
    assert resident_library_bytes(CFG, 3) * 2 == resident_library_bytes(CFG, 6)
