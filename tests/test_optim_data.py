"""Optimizers vs. numpy references; data pipeline properties."""
import jax
import jax.numpy as jnp
import numpy as np
from conftest import given_or_grid

from repro.data.partition import ClientSampler, dirichlet_partition, iid_partition
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification, make_instruction)
from repro.optim.base import adamw, clip_by_global_norm, cosine_schedule, sgd
from repro.optim.zeroth import kseed_apply, kseed_coeffs, spsa_grad


# ------------------------------------------------------------------ optimizers
def test_sgd_matches_numpy():
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    opt = sgd(lr=0.1)
    st_ = opt.init(p)
    p2, _ = opt.step(p, g, st_)
    np.testing.assert_allclose(np.asarray(p2["w"]), [0.95, 2.1], atol=1e-7)


def test_adamw_matches_reference():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(5,)).astype(np.float32)
    g = rng.normal(size=(5,)).astype(np.float32)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.999, 1e-8, 0.01
    opt = adamw(lr, b1, b2, eps, wd, clip=None)
    p = {"w": jnp.asarray(w)}
    state = opt.init(p)
    m = np.zeros_like(w)
    v = np.zeros_like(w)
    for t in range(1, 4):
        p, state = opt.step(p, {"w": jnp.asarray(g)}, state)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / (1 - b1 ** t), v / (1 - b2 ** t)
        w = w - lr * (mh / (np.sqrt(vh) + eps) + wd * w)
    np.testing.assert_allclose(np.asarray(p["w"]), w, atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 5.0) < 1e-6
    total = float(jnp.sqrt(clipped["a"][0] ** 2 + clipped["b"][0] ** 2))
    assert abs(total - 1.0) < 1e-5


def test_clip_zero_gradients_scale_exactly_one():
    """Regression (ISSUE 10): the old ``max_norm / (gn + 1e-9)`` form gave a
    huge-but-finite scale on an all-zero gradient tree; the ``where``-guarded
    form must return the gradients bit-exactly unscaled."""
    g = {"a": jnp.zeros((3, 4)), "b": jnp.zeros((7,))}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == 0.0
    for x, y in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(clipped)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_clip_below_threshold_is_identity():
    """Norms under the bound must not be rescaled at all (the legacy form
    multiplied by ``min(1, max/(gn+eps))`` ≈ 1 − eps·…, a real perturbation)."""
    g = {"a": jnp.array([0.3, -0.4])}        # gn = 0.5 < 1.0
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 0.5) < 1e-7
    assert np.array_equal(np.asarray(clipped["a"]), np.asarray(g["a"]))


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.array(0))) == 0.0
    assert abs(float(sched(jnp.array(10))) - 1.0) < 1e-6
    assert float(sched(jnp.array(100))) < 0.11


def test_spsa_estimates_gradient_direction():
    """On a quadratic the SPSA estimate correlates with the true gradient."""
    target = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    p = {"w": jnp.zeros(3)}
    g, _ = spsa_grad(loss, p, jax.random.PRNGKey(0), eps=1e-3, n_samples=64)
    true_g = jax.grad(loss)(p)
    cos = (jnp.sum(g["w"] * true_g["w"]) /
           (jnp.linalg.norm(g["w"]) * jnp.linalg.norm(true_g["w"]) + 1e-9))
    assert float(cos) > 0.5


def test_kseed_roundtrip_deterministic():
    p = {"w": jnp.ones(4)}

    def loss(t):
        return jnp.sum(t["w"] ** 2)

    seeds = [1, 2, 3]
    c = kseed_coeffs(loss, p, seeds)
    p1 = kseed_apply(p, seeds, [float(x) for x in c], lr=0.01)
    p2 = kseed_apply(p, seeds, [float(x) for x in c], lr=0.01)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]))
    assert float(loss(p1)) < float(loss(p))


# ------------------------------------------------------------------ data
def test_classification_label_recoverable():
    spec = DATASETS["agnews"]
    tokens, labels = make_classification(spec)
    assert tokens.shape == (spec.n_samples, spec.seq_len)
    assert labels.min() >= 0 and labels.max() == spec.n_classes - 1
    # the topic signal exists: per-class mean token histograms differ
    h0 = np.bincount(tokens[labels == 0].ravel(), minlength=spec.vocab)
    h1 = np.bincount(tokens[labels == 1].ravel(), minlength=spec.vocab)
    assert np.abs(h0 / h0.sum() - h1 / h1.sum()).sum() > 0.1


def test_classification_batch_layout():
    spec = DATASETS["yelp_p"]
    tokens, labels = make_classification(spec)
    b = classification_batch(spec, tokens, labels, np.arange(4))
    assert (b["labels"][:, :-1] == -100).all()
    assert (b["labels"][:, -1] >= spec.vocab - spec.n_classes - 1).all()


@given_or_grid([dict(n_clients=n, alpha=a) for n in (2, 7, 20)
                for a in (0.1, 1.0, 10.0)],
               lambda st: dict(n_clients=st.integers(2, 20),
                               alpha=st.floats(0.1, 10.0)),
               max_examples=20)
def test_dirichlet_partition_properties(n_clients, alpha):
    labels = np.random.default_rng(0).integers(0, 4, 400)
    shards = dirichlet_partition(labels, n_clients, alpha, seed=1)
    assert len(shards) == n_clients
    for s in shards:
        assert len(s) >= 2                      # floor guarantee
        assert len(np.unique(s)) == len(s)      # no dup inside a shard


def test_iid_partition_covers_all():
    shards = iid_partition(100, 7, seed=0)
    allidx = np.sort(np.concatenate(shards))
    np.testing.assert_array_equal(allidx, np.arange(100))


def test_client_sampler_epochs():
    s = ClientSampler(np.arange(10), batch_size=4, seed=0)
    seen = np.concatenate([s.next_indices() for _ in range(5)])
    assert set(seen) <= set(range(10))
    assert len(seen) == 20


def test_instruction_task_structure():
    tokens, labels = make_instruction(n_samples=32, seq_len=32)
    mask = labels != -100
    assert mask.sum() == 32          # exactly one supervised position each
    rows = np.where(mask.any(axis=1))[0]
    assert len(rows) == 32
