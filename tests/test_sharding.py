"""Sharding rules + dry-run plumbing (small fake-device mesh in a subprocess
so the main test process keeps its single real device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import get_config
from repro.launch import input_specs as ispec
from repro.sharding.rules import _maybe

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_maybe_divisibility():
    assert _maybe(64, "model", 16) == "model"
    assert _maybe(14, "model", 16) is None
    assert _maybe(0, "model", 16) is None


def test_shapes_registry():
    assert set(ispec.SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert ispec.SHAPES["train_4k"].global_batch == 256
    assert ispec.SHAPES["long_500k"].seq_len == 524288


def test_long500k_support_matrix():
    assert ispec.supported(get_config("falcon_mamba_7b"), "long_500k")
    assert ispec.supported(get_config("hymba_1_5b"), "long_500k")
    assert ispec.supported(get_config("gemma_2b"), "long_500k")  # SWA variant
    assert not ispec.supported(get_config("seamless_m4t_large_v2"), "long_500k")


def test_train_specs_shapes():
    cfg = get_config("qwen2_0_5b")
    cfg2, case, specs = ispec.input_specs(cfg, "train_4k")
    assert specs["tokens"].shape == (32, 1, 8, 4096)   # C × ls × b × S
    assert cfg2.sliding_window is None                 # full attn off-500k
    cfgm, _, dspecs = ispec.input_specs(get_config("deepseek_67b"), "decode_32k")
    token, cache, idx, embeds, enc_len = dspecs
    assert cache["k"].shape == (95, 128, 32768, 8, 128)


def test_param_specs_cover_all_leaves():
    """Every param/adapters leaf gets a spec of matching rank."""
    from repro.sharding.rules import Ruleset
    from repro.models import transformer as T
    for arch in ("qwen2_0_5b", "olmoe_1b_7b", "falcon_mamba_7b", "hymba_1_5b",
                 "seamless_m4t_large_v2"):
        cfg = get_config(arch)
        a_params = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0), cfg))

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        rules = Ruleset(FakeMesh(), cfg)
        specs = rules.params(a_params)
        flat_p = jax.tree_util.tree_leaves(a_params)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) <= len(leaf.shape), (arch, leaf.shape, spec)
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax == "model":
                    assert dim % 16 == 0, (arch, leaf.shape, spec)


@pytest.mark.slow
def test_dryrun_subprocess_small():
    """Full dry-run path for one (arch, shape) — isolated process because it
    forces 512 fake devices before jax init."""
    code = textwrap.dedent("""
        from repro.launch.dryrun import run_case
        rec = run_case("qwen2_1_5b", "decode_32k", verbose=False)
        import json; print("JSON" + json.dumps({k: rec[k] for k in
            ("arch", "shape", "chips")}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("JSON")][0]
    rec = json.loads(line[4:])
    assert rec == {"arch": "qwen2_1_5b", "shape": "decode_32k", "chips": 256}
