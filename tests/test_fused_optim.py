"""Fused update+optimizer path (ISSUE 10): every route of the single-pass
step — forced Pallas kernel (interpret here), backend-aware XLA fallback,
int8 quantized state — against the legacy multi-``tree_map`` baseline;
blockwise quantization error bounds; the int8 loss trajectory on a quadratic
fixture; cohort ≡ sequential parity + one-compile steady state under the
fused/int8 engine path; and bit-identical kill/resume with ``opt_bits=8``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import (DATASETS, classification_batch,
                                  make_classification)
from repro.fed.engine import FedSim
from repro.fed.registry import make_strategy, run_experiment
from repro.models.config import ChainConfig, FedConfig
from repro.optim.base import adamw, cosine_schedule, make_optimizer, sgd
from repro.optim.quant import (QBLOCK, dequantize_blockwise,
                               quantize_blockwise, zeros_quantized)

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
KEY = jax.random.PRNGKey(0)


def _tree(seed=0, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"a": jax.random.normal(k1, (33, 97)) * scale,
            "b": jax.random.normal(k2, (130,)) * scale}


def _run(opt, params, grads_list):
    p, st = params, opt.init(params)
    for g in grads_list:
        p, st = opt.step(p, g, st)
    return p, st


def _assert_tree_close(a, b, atol=1e-6, rtol=1e-5):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol, rtol=rtol)


# ====================================================== fp32 parity per route
@pytest.mark.parametrize("fused", [None, True])
def test_fused_adamw_matches_legacy(fused):
    """Single-pass AdamW (XLA fallback and forced kernel) ≡ the legacy
    multi-pass step, including clip scaling, weight decay, and the
    bias-correction ``count`` over several steps."""
    params = _tree(0)
    grads = [_tree(s, 3.0) for s in (1, 2, 3)]     # norms > clip → scaling on
    kw = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01, clip=1.0)
    ref, st_ref = _run(adamw(1e-2, fused=False, **kw), params, grads)
    got, st_got = _run(adamw(1e-2, fused=fused, **kw), params, grads)
    _assert_tree_close(ref, got)
    _assert_tree_close(st_ref["mu"], st_got["mu"])
    _assert_tree_close(st_ref["nu"], st_got["nu"])
    assert int(st_got["count"]) == 3


@pytest.mark.parametrize("fused", [None, True])
@pytest.mark.parametrize("momentum", [0.0, 0.9])
def test_fused_sgd_matches_legacy(fused, momentum):
    params = _tree(0)
    grads = [_tree(s, 3.0) for s in (1, 2)]
    ref, _ = _run(sgd(1e-2, momentum=momentum, clip=0.5, fused=False),
                  params, grads)
    got, _ = _run(sgd(1e-2, momentum=momentum, clip=0.5, fused=fused),
                  params, grads)
    _assert_tree_close(ref, got)


def test_fused_respects_lr_schedule():
    """A callable lr resolves against the same ``count`` on every route."""
    sched = cosine_schedule(1e-2, warmup_steps=2, total_steps=10)
    params, grads = _tree(0), [_tree(s) for s in (1, 2, 3, 4)]
    ref, _ = _run(adamw(sched, fused=False), params, grads)
    got, _ = _run(adamw(sched, fused=True), params, grads)
    _assert_tree_close(ref, got)


# ========================================================== int8 state route
def test_int8_kernel_matches_ref():
    """The in-kernel dequant→update→requant ≡ the XLA reference built from
    ``optim.quant`` primitives, for AdamW and SGD-momentum."""
    params = _tree(0)
    grads = [_tree(s, 2.0) for s in (1, 2, 3)]
    for make in (lambda f: adamw(1e-2, opt_bits=8, fused=f),
                 lambda f: sgd(1e-2, momentum=0.9, opt_bits=8, fused=f)):
        ref, st_ref = _run(make(None), params, grads)
        got, st_got = _run(make(True), params, grads)
        _assert_tree_close(ref, got, atol=1e-5, rtol=1e-4)
        for k in st_ref:
            _assert_tree_close(st_ref[k], st_got[k], atol=1, rtol=0)


def test_int8_state_structure_and_dtypes():
    opt = adamw(1e-2, opt_bits=8)
    st = opt.init(_tree(0))
    assert set(st) == {"count", "mu_q", "mu_s", "nu_q", "nu_s"}
    assert st["mu_q"]["a"].dtype == jnp.int8
    assert st["mu_q"]["a"].shape == (33, 97)
    assert st["mu_s"]["a"].dtype == jnp.float32
    assert st["mu_s"]["a"].shape == ((33 * 97 + QBLOCK - 1) // QBLOCK,)


def test_int8_loss_trajectory_tracks_fp32():
    """Quadratic fixture ½‖w − w*‖²: the int8-state AdamW loss trajectory
    stays within a few percent of fp32 and reaches the same basin."""
    target = jax.random.normal(jax.random.PRNGKey(7), (257,))
    loss = lambda p: 0.5 * jnp.sum((p["w"] - target) ** 2)
    gfn = jax.jit(jax.value_and_grad(loss))

    def traj(bits):
        opt = adamw(0.05, clip=None, weight_decay=0.0, opt_bits=bits)
        p = {"w": jnp.zeros(257)}
        st = opt.init(p)
        out = []
        for _ in range(60):
            l, g = gfn(p)
            out.append(float(l))
            p, st = opt.step(p, g, st)
        return np.asarray(out)

    l32, l8 = traj(32), traj(8)
    assert l8[-1] < 0.05 * l8[0]                 # converges
    np.testing.assert_allclose(l8, l32, rtol=0.15, atol=0.5)


# ======================================================= quantizer primitives
def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 300)) * 4.0
    q, s = quantize_blockwise(x)
    back = dequantize_blockwise(q, s)
    # per-element error ≤ half a quantization step of its own block
    err = np.abs(np.asarray(back) - np.asarray(x))
    step = np.repeat(np.asarray(s), QBLOCK)[:x.size].reshape(x.shape)
    assert np.all(err <= 0.5 * step + 1e-7)
    assert q.dtype == jnp.int8 and q.shape == x.shape


def test_quantize_zero_block_is_exact():
    x = jnp.zeros((QBLOCK * 2,))
    q, s = quantize_blockwise(x)
    assert np.all(np.asarray(s) == 0.0)
    assert np.all(np.asarray(dequantize_blockwise(q, s)) == 0.0)
    zq, zs = zeros_quantized((QBLOCK * 2,))
    assert np.array_equal(np.asarray(zq), np.asarray(q))
    assert np.array_equal(np.asarray(zs), np.asarray(s))


def test_quantize_partial_trailing_block():
    x = jnp.arange(1.0, QBLOCK + 8.0)            # one full + 7-elem block
    back = dequantize_blockwise(*quantize_blockwise(x))
    assert back.shape == x.shape
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=0.01, atol=0.05)


# ================================================= engine-level int8 + fused
def _build_sim(seed=3):
    spec = dataclasses.replace(DATASETS["agnews"], vocab=CFG.vocab_size)
    tokens, labels = make_classification(spec)
    batch_fn = lambda idx: {k: jnp.asarray(v) for k, v in
                            classification_batch(spec, tokens, labels,
                                                 idx).items()}
    fed = FedConfig(n_clients=6, clients_per_round=3, seed=seed)
    return FedSim(CFG, fed, tokens, labels, batch_fn, batch_size=4,
                  memory_constrained=False)


@pytest.mark.parametrize("opt_bits", [32, 8])
def test_cohort_matches_sequential_under_fused(opt_bits):
    """Cohort ≡ sequential parity holds on the single-pass path (both
    precisions), and the steady state stays at one compile per plan."""
    chain = ChainConfig(window=2, local_steps=2, lr=1e-3, opt_bits=opt_bits)

    def run(path):
        sim = _build_sim()
        strat = make_strategy("chainfed", CFG, chain, KEY, use_foat=False)
        strat._foat_done = True
        for r in range(2):
            clients = sim.sample_clients(strat.memory_method,
                                         **strat.memory_kwargs(r))
            getattr(strat, "round" if path == "cohort"
                    else "sequential_round")(sim, clients, r)
        return strat

    a, b = run("cohort"), run("sequential")
    tol = dict(atol=1e-6, rtol=1e-5) if opt_bits == 32 else \
        dict(atol=1e-4, rtol=1e-3)
    _assert_tree_close(a.adapters, b.adapters, **tol)
    _assert_tree_close(a.head, b.head, **tol)
    for f in a.engine._cohort.values():
        if hasattr(f, "_cache_size"):
            assert f._cache_size() == 1


def test_opt_bits8_kill_resume_bit_identical(tmp_path):
    """int8 optimizer state (and the rest of the run) survives a mid-run
    kill bit for bit — the ISSUE 10 checkpoint criterion."""
    chain = ChainConfig(window=2, local_steps=1, lr=3e-3, opt_bits=8)
    kw = dict(cfg=CFG, chain=chain,
              fed=FedConfig(n_clients=6, clients_per_round=3, seed=3),
              batch_size=4, memory_constrained=False, rounds=4, eval_every=2)
    full = run_experiment("chainfed", **kw)
    ck = tmp_path / "exp.msgpack"
    run_experiment("chainfed", **kw, checkpoint_every=2, checkpoint_path=ck,
                   halt_after=2)
    resumed = run_experiment("chainfed", **kw, resume=ck)
    assert full.history == resumed.history
    for x, y in zip(jax.tree_util.tree_leaves(full.strategy.adapters),
                    jax.tree_util.tree_leaves(resumed.strategy.adapters)):
        assert x.dtype == y.dtype and np.array_equal(np.asarray(x),
                                                     np.asarray(y))


def test_int8_moments_round_trip_checkpoint_io(tmp_path):
    """``ckpt.io`` must carry int8 payloads + fp32 scales losslessly."""
    from repro.ckpt.io import load_state, save_state
    opt = adamw(1e-2, opt_bits=8)
    p = _tree(0)
    p2, st = _run(opt, p, [_tree(1, 2.0)])
    save_state(tmp_path / "m.msgpack", {"st": st})
    got = load_state(tmp_path / "m.msgpack")["st"]
    for k in ("mu_q", "nu_q"):
        for x, y in zip(jax.tree_util.tree_leaves(st[k]),
                        jax.tree_util.tree_leaves(got[k])):
            assert y.dtype == jnp.int8
            assert np.array_equal(np.asarray(x), np.asarray(y))
    for k in ("mu_s", "nu_s"):
        for x, y in zip(jax.tree_util.tree_leaves(st[k]),
                        jax.tree_util.tree_leaves(got[k])):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_make_optimizer_rejects_bad_bits():
    with pytest.raises(ValueError, match="opt_bits"):
        make_optimizer("adamw", 1e-3, opt_bits=16)
