"""Privacy subsystem (ISSUE 6): the RDP accountant against the closed-form
Gaussian bound, in-graph DP clipping/noise semantics, bit-exact mask
cancellation with and without dropouts, secure-agg ≡ plain FedAvg on the
sync path, dropout recovery on the event heap, seed-reproducibility of DP
runs, and the comm-model overhead of both mechanisms."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.memory import privacy_comm_overhead
from repro.fed.privacy import (DEFAULT_RDP_ORDERS, DPConfig, RDPAccountant,
                               SecureAggConfig, SecureSession, clip_cohort,
                               enable_dp, enable_secure_agg,
                               make_private_aggregate, rdp_gaussian)
from repro.fed.registry import make_strategy, run_experiment
from repro.fed.strategies import (as_rng_aggregate, cohort_fedavg,
                                  cohort_norms)
from repro.models.config import ChainConfig, FedConfig

CFG = get_config("bert_tiny").replace(n_layers=4, d_model=64, d_ff=128)
CHAIN = ChainConfig(window=2, local_steps=1, lr=3e-3)
KEY = jax.random.PRNGKey(0)


def _experiment(**kw):
    fed = FedConfig(n_clients=6, clients_per_round=3, seed=3)
    return run_experiment(kw.pop("method", "full_adapters"), cfg=CFG,
                          chain=CHAIN, fed=fed, batch_size=4,
                          memory_constrained=False, eval_every=1, **kw)


def _cohort(c=4, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(c, 5, 3)) * scale, jnp.float32),
            "b": jnp.asarray(rng.normal(size=(c, 7)) * scale, jnp.float32)}


# ------------------------------------------------------------ RDP accountant
def test_accountant_matches_closed_form_gaussian_bound():
    """q = 1 (full cohort every commit): RDP(α) = T·α/(2σ²) exactly, so ε
    must equal the hand-computed grid minimum of T·α/(2σ²) +
    log(1/δ)/(α−1)."""
    sigma, steps, delta = 1.3, 7, 1e-5
    acc = RDPAccountant()
    acc.step(sigma, q=1.0, steps=steps)
    eps, order = acc.epsilon(delta)
    orders = np.array(DEFAULT_RDP_ORDERS, np.float64)
    expect = steps * orders / (2 * sigma ** 2) \
        + math.log(1 / delta) / (orders - 1)
    assert eps == pytest.approx(float(expect.min()), rel=1e-12)
    assert order == DEFAULT_RDP_ORDERS[int(expect.argmin())]


def test_accountant_subsampling_and_composition():
    """Poisson subsampling only helps (RDP_q ≤ RDP_1 per order), ε grows
    with composition, and an untouched accountant reports ε = 0."""
    for a in (2, 5, 32):
        assert rdp_gaussian(a, 1.2, 0.25) <= rdp_gaussian(a, 1.2, 1.0)
        assert rdp_gaussian(a, 1.2, 0.0) == 0.0
    assert rdp_gaussian(3, 0.0, 0.5) == float("inf")
    acc = RDPAccountant()
    assert acc.epsilon(1e-5)[0] == 0.0
    seen = []
    for _ in range(4):
        acc.step(1.0, q=0.5)
        seen.append(acc.epsilon(1e-5)[0])
    assert all(b > a > 0 for a, b in zip(seen, seen[1:]))


# ----------------------------------------------------------- DP aggregation
def test_clip_cohort_bounds_global_norm():
    deltas = _cohort(c=5, scale=3.0)
    clipped = clip_cohort(deltas, 1.0)
    assert float(cohort_norms(clipped).max()) <= 1.0 + 1e-5
    # below-bound updates pass through unscaled
    small = _cohort(c=5, scale=1e-3)
    for k in small:
        np.testing.assert_allclose(clip_cohort(small, 1.0)[k], small[k],
                                   rtol=1e-6)


def test_private_aggregate_sigma0_is_clipped_uniform_fedavg():
    """With σ = 0 the DP wrapper is exactly clip → *uniform*-weight FedAvg —
    sample-count weights must be ignored (they would make sensitivity
    data-dependent)."""
    deltas = _cohort(c=4, scale=2.0)
    t0 = tree0 = {k: jnp.zeros(v.shape[1:], v.dtype) for k, v in
                  deltas.items()}
    skewed = jnp.asarray([10.0, 1.0, 1.0, 1.0], jnp.float32)
    dp = DPConfig(clip=0.7, noise_multiplier=0.0)
    agg = make_private_aggregate(dp, as_rng_aggregate(None))
    got = agg(t0, deltas, skewed, None, jax.random.PRNGKey(1))
    want = cohort_fedavg(tree0, clip_cohort(deltas, 0.7),
                         jnp.ones_like(skewed), None)
    for k in got:
        np.testing.assert_allclose(got[k], want[k], atol=1e-6)


def test_dp_run_reproducible_and_epsilon_monotone():
    dp = {"clip": 0.5, "noise_multiplier": 1.1, "seed": 9}
    a = _experiment(rounds=3, dp=dp)
    b = _experiment(rounds=3, dp=dp)
    assert [(m.loss, m.dp_epsilon) for m in a.history] == \
           [(m.loss, m.dp_epsilon) for m in b.history]
    eps = [m.dp_epsilon for m in a.history]
    assert eps[0] > 0 and eps == sorted(eps)
    # noise actually perturbs the trajectory vs the clean run
    clean = _experiment(rounds=3)
    assert a.history[-1].loss != clean.history[-1].loss


def test_enable_dp_after_compile_refuses():
    r = _experiment(rounds=1)
    with pytest.raises(RuntimeError, match="enable_dp after"):
        enable_dp(r.strategy, DPConfig())


# -------------------------------------------------------- secure aggregation
def _session(cids=(11, 3, 7, 5), seed=2):
    return SecureSession(SecureAggConfig(seed=seed),
                         jax.random.PRNGKey(seed), cids)


def _toy_trees(sess):
    return {c: {"w": jnp.asarray(np.random.default_rng(c).normal(size=(6, 2)),
                                 jnp.float32),
                "b": jnp.asarray(np.random.default_rng(c + 99).normal(size=3),
                                 jnp.float32)}
            for c in sess.cids}


def test_masks_cancel_bitexact_full_roster():
    sess = _session()
    trees = _toy_trees(sess)
    total = sess.unmask_sum([sess.mask_update(c, trees[c])
                             for c in sess.cids], sess.cids)
    for k in ("w", "b"):
        want = sum(sess.quantize(trees[c])[k] for c in sess.cids)
        assert jnp.all(total[k] == want), k       # int32, bit for bit
        # masked uploads are NOT the plaintext
        assert not jnp.all(sess.mask_update(sess.cids[0],
                                            trees[sess.cids[0]])[k]
                           == sess.quantize(trees[sess.cids[0]])[k])


def test_masks_cancel_bitexact_with_dropped_client():
    """Dropout recovery: survivors' sum minus the reconstructed masks of the
    dropped member equals the survivors' plaintext sum bit-exactly."""
    sess = _session()
    trees = _toy_trees(sess)
    dropped = sess.cids[1]
    survivors = [c for c in sess.cids if c != dropped]
    total = sess.unmask_sum([sess.mask_update(c, trees[c])
                             for c in survivors], survivors)
    for k in ("w", "b"):
        want = sum(sess.quantize(trees[c])[k] for c in survivors)
        assert jnp.all(total[k] == want), k


def test_secure_sync_round_matches_plain_fedavg():
    plain = _experiment(rounds=1)
    masked = _experiment(rounds=1, secure_agg=True)
    for k in plain.strategy.adapters:
        np.testing.assert_allclose(np.asarray(masked.strategy.adapters[k]),
                                   np.asarray(plain.strategy.adapters[k]),
                                   atol=1e-4)
    assert masked.history[-1].comm_bytes > plain.history[-1].comm_bytes


def test_secure_semisync_dropout_recovers_and_commits():
    r = _experiment(rounds=3, mode="semisync", secure_agg=True,
                    scheduler_opts={"straggler": "drop"},
                    faults={"dropout_prob": 0.3, "seed": 5})
    assert len(r.history) == 3
    assert all(np.isfinite(m.loss) for m in r.history)


def test_secure_composes_with_dp():
    dp = {"clip": 0.5, "noise_multiplier": 1.0, "seed": 4}
    r = _experiment(rounds=2, dp=dp, secure_agg=True)
    assert all(np.isfinite(m.loss) for m in r.history)
    assert r.history[-1].dp_epsilon > 0


def test_enable_secure_agg_rejects_incompatible():
    fedra = make_strategy("fedra", CFG, CHAIN, KEY)
    with pytest.raises(ValueError, match="not a linear"):
        enable_secure_agg(fedra)
    robust = make_strategy("full_adapters", CFG, CHAIN, KEY)
    robust.aggregator = "trimmed_mean"
    with pytest.raises(ValueError, match="plaintext"):
        enable_secure_agg(robust)


# ------------------------------------------------------------- comm model
def test_privacy_comm_overhead_accounting():
    assert privacy_comm_overhead(4) == 0
    assert privacy_comm_overhead(4, secure=True) == 3 * 3 * 32
    assert privacy_comm_overhead(4, dp=True) == 16
    assert privacy_comm_overhead(1, secure=True) == 0   # no pairs
    strat = make_strategy("full_adapters", CFG, CHAIN, KEY)
    base = strat.comm_bytes_per_round()
    enable_secure_agg(strat, SecureAggConfig(cohort=3))
    enable_dp(strat, DPConfig())
    assert strat.comm_bytes_per_round() == \
        base + privacy_comm_overhead(3, secure=True, dp=True)
